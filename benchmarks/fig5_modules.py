"""Paper Fig. 5: per-module computation / communication vs k'.

Module 1 = plaintext top-k' search; Module 2a = encrypted re-rank;
Module 2b = direct fetch; Module 2c = k-of-k' OT.  Both crypto backends for
2a (the paper's Paillier and the TPU-native RLWE).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import FULL, emit, timeit
from repro.core import accounting as acc
from repro.crypto import ot as ot_mod
from repro.crypto import paillier as pai
from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import distributed_topk


def run() -> None:
    rng = np.random.default_rng(0)
    dim = 768
    n_docs = 100_000 if FULL else 20_000
    emb = synth.uniform_corpus(rng, n_docs, dim)
    index = FlatIndex.build(emb)
    q = synth.queries_near_corpus(rng, emb, 1)
    qj = jnp.asarray(q)

    kprimes = [40, 80, 160, 320] if not FULL else [40, 80, 160, 320, 640]

    params = rlwe.RlweParams()
    sk = rlwe.keygen(params, rng)
    ct = rlwe.encrypt_query(sk, q[0], rng)
    pk_paillier = pai.keygen(512)
    enc_q = pai.encrypt_vector(pk_paillier.pub, q[0])

    for kp in kprimes:
        # module 1: plaintext top-k' scan over all N
        us1 = timeit(lambda: jax.block_until_ready(
            distributed_topk(index, qj, kp).values), repeat=3)
        emit(f"fig5/module1_topk_k{kp}", us1, f"N={n_docs}")

        cands = np.asarray(index.rows(
            np.asarray(distributed_topk(index, qj, kp).indices)[0]))

        # module 2a (rlwe): pack + encrypted scores + decrypt
        def m2a_rlwe():
            packed = rlwe.pack_candidates(params, cands)
            res = rlwe.encrypted_scores(params, ct, packed)
            return rlwe.decrypt_scores(sk, res)

        us2 = timeit(m2a_rlwe, repeat=2)
        emit(f"fig5/module2a_rlwe_k{kp}", us2,
             f"bytes={acc.rlwe_scores_bytes(kp, dim)}")

        # module 2a (paillier) — measured on a slice, scaled (exactly linear)
        slice_n = min(8, kp)
        us_slice = timeit(lambda: pai.encrypted_scores(
            pk_paillier.pub, enc_q, cands[:slice_n]), repeat=1)
        emit(f"fig5/module2a_paillier_k{kp}", us_slice * kp / slice_n,
             f"bytes={acc.paillier_scores_bytes(kp, 512)};extrapolated")

        # module 2b: direct fetch (bytes only — fetch is index lookup)
        emit(f"fig5/module2b_direct_k{kp}", 0.0,
             f"bytes={5 * 4 + 5 * 1024}")

        # module 2c: OT over k' docs of 1 KiB
        msgs = [b"d" * 1024 for _ in range(kp)]
        us3 = timeit(lambda: ot_mod.run_ot(msgs, [0, 1, 2, 3, 4]), repeat=1)
        _, wire = ot_mod.run_ot(msgs, [0, 1, 2, 3, 4])
        emit(f"fig5/module2c_ot_k{kp}", us3, f"bytes={wire}")
