#!/usr/bin/env python3
"""Docs CI check: intra-repo links and documented-symbol imports.

Scans README.md and every markdown file under docs/ for

  * relative links ``[text](path)`` — each target must exist in the repo
    (external ``http(s)://`` / ``mailto:`` links and pure ``#anchor``
    fragments are skipped);
  * backticked dotted symbols `` `repro.x.y[.attr...]` `` — each must
    resolve: the longest importable module prefix is imported and the
    remaining names are walked with getattr (dataclass fields and
    annotated attributes count, so documented per-field rows like
    ``repro.serve.ServeRequest.request_id`` resolve too).

Exit 0 iff every link resolves and every documented symbol imports.

    PYTHONPATH=src scripts/check_docs.py [repo_root]
"""

from __future__ import annotations

import importlib
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMBOL_RE = re.compile(r"`(repro(?:\.[A-Za-z_]\w*)+)`")
SKIP_SCHEMES = ("http://", "https://", "mailto:")


def markdown_files(root: str) -> list:
    files = [os.path.join(root, "README.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for dirpath, _, names in os.walk(docs):
            files.extend(os.path.join(dirpath, n)
                         for n in sorted(names) if n.endswith(".md"))
    return [f for f in files if os.path.isfile(f)]


def check_links(path: str, text: str, root: str) -> list:
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(SKIP_SCHEMES):
            continue
        target = target.split("#", 1)[0]
        if not target:                       # pure #anchor
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, root)}: broken link "
                          f"-> {target}")
    return errors


def resolve_symbol(dotted: str) -> None:
    """Import the longest module prefix of ``dotted``, then walk attrs.
    Raises on failure."""
    parts = dotted.split(".")
    module = None
    mod_err = None
    for i in range(len(parts), 0, -1):
        try:
            module = importlib.import_module(".".join(parts[:i]))
            rest = parts[i:]
            break
        except ImportError as e:
            mod_err = e
    else:
        raise ImportError(f"no importable module prefix: {mod_err}")
    obj = module
    for name in rest:
        try:
            obj = getattr(obj, name)
        except AttributeError:
            # dataclass fields without defaults / annotated-only attrs are
            # real API surface but not class attributes
            fields = getattr(obj, "__dataclass_fields__", {})
            annotations = getattr(obj, "__annotations__", {})
            if name in fields or name in annotations:
                return
            raise


def check_symbols(path: str, text: str, root: str) -> list:
    errors = []
    for dotted in sorted(set(SYMBOL_RE.findall(text))):
        try:
            resolve_symbol(dotted)
        except Exception as e:              # noqa: BLE001 — report any failure
            errors.append(f"{os.path.relpath(path, root)}: `{dotted}` does "
                          f"not resolve: {type(e).__name__}: {e}")
    return errors


def main() -> int:
    root = os.path.abspath(
        sys.argv[1] if len(sys.argv) > 1
        else os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(root, "src"))
    files = markdown_files(root)
    if not files:
        print("FAIL: no markdown files found", file=sys.stderr)
        return 2
    errors = []
    n_links = n_syms = 0
    for path in files:
        with open(path) as f:
            text = f.read()
        n_links += len([t for t in LINK_RE.findall(text)
                        if not t.startswith(SKIP_SCHEMES)])
        n_syms += len(set(SYMBOL_RE.findall(text)))
        errors += check_links(path, text, root)
        errors += check_symbols(path, text, root)
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    print(f"checked {len(files)} files: {n_links} intra-repo links, "
          f"{n_syms} documented symbols, {len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
