"""Embedding-inversion attack proxies (paper Section 5.2 / Fig. 4).

The paper attacks perturbed embeddings with Vec2Text and scores SacreBLEU of
the reconstruction.  No pretrained inversion model is available offline, so
we measure the same signal — semantic recoverability as a function of the
perturbation — with two standard proxies:

  * nearest-neighbour attack: the adversary holds an auxiliary corpus of
    (tokens, embedding) pairs and decodes an observed embedding to its nearest
    auxiliary document; score = token-set F1 vs the true query tokens.
  * linear decoder attack: ridge regression from embeddings to bag-of-words
    on auxiliary data; score = F1 of the top-predicted tokens.

Both produce Fig.-4-shaped curves: near-perfect recovery at r=0 decaying to
chance as r grows, with the knee in the paper's r in [0.02, 0.1] band.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.data.synth import TokenCorpus, unit


def token_f1(pred: set, true: set) -> float:
    if not pred or not true:
        return 0.0
    tp = len(pred & true)
    if tp == 0:
        return 0.0
    precision = tp / len(pred)
    recall = tp / len(true)
    return 2 * precision * recall / (precision + recall)


@dataclasses.dataclass
class NearestNeighborAttack:
    """Decode an embedding to the closest auxiliary document's tokens.

    Note (EXPERIMENTS.md): a 1-NN decoder over a fixed aux corpus is the
    noise-OPTIMAL attacker — in n dims a random perturbation projects only
    ~r/sqrt(n) onto any particular neighbour direction, so this proxy needs
    ~sqrt(n)-scaled radii to degrade where Vec2Text's generative decoder
    (the paper's attack) already fails.  The privacy statement is therefore
    conservative: radii that defeat 1-NN certainly defeat Vec2Text.
    """

    aux: TokenCorpus

    def decode_index(self, observed: np.ndarray) -> int:
        scores = self.aux.embeddings @ unit(observed)
        return int(np.argmax(scores))

    def reconstruct(self, observed: np.ndarray) -> set:
        return self.aux.token_sets[self.decode_index(observed)]

    def score(self, observed: np.ndarray, true_tokens: set) -> float:
        return token_f1(self.reconstruct(observed), true_tokens)


@dataclasses.dataclass
class LinearDecoderAttack:
    """Ridge-regression bag-of-words decoder trained on auxiliary pairs."""

    aux: TokenCorpus
    ridge: float = 1e-2
    top_m: int = 24

    def __post_init__(self):
        X = self.aux.embeddings                       # (D, n)
        Y = np.zeros((X.shape[0], self.aux.vocab), np.float32)
        for i, toks in enumerate(self.aux.token_sets):
            for t in toks:
                Y[i, t] = 1.0
        gram = X.T @ X + self.ridge * np.eye(X.shape[1], dtype=np.float32)
        self.W = np.linalg.solve(gram, X.T @ Y)       # (n, vocab)

    def reconstruct(self, observed: np.ndarray) -> set:
        logits = unit(observed) @ self.W
        return set(np.argsort(-logits)[: self.top_m].tolist())

    def score(self, observed: np.ndarray, true_tokens: set) -> float:
        return token_f1(self.reconstruct(observed), true_tokens)


def attack_curve(attack, corpus: TokenCorpus, query_ids: Sequence[int],
                 radii: Sequence[float], rng: np.random.Generator) -> np.ndarray:
    """Mean attack score per perturbation radius (Fig. 4a proxy)."""
    out = []
    for r in radii:
        scores = []
        for qi in query_ids:
            e = corpus.embeddings[qi]
            v = unit(rng.normal(size=e.shape))
            scores.append(attack.score(e + r * v, corpus.token_sets[qi]))
        out.append(float(np.mean(scores)))
    return np.asarray(out)


def exact_recovery_curve(attack: NearestNeighborAttack, corpus: TokenCorpus,
                         query_ids: Sequence[int], radii: Sequence[float],
                         rng: np.random.Generator) -> np.ndarray:
    """P[attacker identifies the *literal* query document] per radius —
    the sharper privacy signal (F1 degrades gracefully through semantic
    near-duplicates; exact recovery cliffs at the decision boundary)."""
    out = []
    for r in radii:
        hits = []
        for qi in query_ids:
            e = corpus.embeddings[qi]
            v = unit(rng.normal(size=e.shape))
            hits.append(attack.decode_index(e + r * v) == qi)
        out.append(float(np.mean(hits)))
    return np.asarray(out)


__all__ = ["token_f1", "NearestNeighborAttack", "LinearDecoderAttack",
           "attack_curve", "exact_recovery_curve"]
