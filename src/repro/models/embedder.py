"""Text embedding model for the RAG service: mean-pooled bidirectional
transformer encoder over hashed tokens, unit-normalized output.

This is the in-framework stand-in for gtr-t5-base / MiniLM: the protocol and
benchmarks only need *some* shared embedding model both sides can run; its
dimension is what the paper's theory cares about.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.transformer import TransformerConfig


def encoder_config(dim: int = 768, *, vocab: int = 32768,
                   n_layers: int = 4) -> TransformerConfig:
    return TransformerConfig(
        name=f"embedder-{dim}", n_layers=n_layers, d_model=dim,
        n_heads=max(4, dim // 128), n_kv_heads=max(4, dim // 128),
        d_ff=dim * 4, vocab=vocab, d_head=128, dtype="float32", remat=False)


def init_params(key, cfg: TransformerConfig):
    return transformer.init_params(key, cfg)


def embed(params, cfg: TransformerConfig, tokens, mask=None):
    """tokens (B, S) -> unit-norm embeddings (B, d_model).

    Bidirectional (causal=False path via the chunked attention) + mean pool.
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]

    def scan_fn(x, layer_p):
        h, _ = layers.attention_fwd(
            layer_p["attn"], layers.rms_norm(x, layer_p["attn_norm"]),
            cfg.attn_spec, positions=positions, causal=False)
        x = x + h
        h = layers.mlp_fwd(layer_p["mlp"], layers.rms_norm(x, layer_p["mlp_norm"]))
        return x + h, None

    x, _ = jax.lax.scan(scan_fn, x, params["layers"])
    x = layers.rms_norm(x, params["final_norm"])
    if mask is not None:
        x = x * mask[..., None]
        pooled = x.sum(1) / jnp.maximum(mask.sum(1)[:, None], 1.0)
    else:
        pooled = x.mean(axis=1)
    return pooled / (jnp.linalg.norm(pooled, axis=-1, keepdims=True) + 1e-6)


__all__ = ["encoder_config", "init_params", "embed"]
