"""Training step builders + the driver loop.

`make_train_step(loss_fn, opt_cfg, ...)` returns a pure function
(params, opt_state, batch) -> (params, opt_state, metrics) suitable for
jit/pjit with donated params/opt_state.  Supports gradient accumulation over
microbatches (scan) — the accumulation loop is also where compute/collective
overlap comes from under XLA's latency-hiding scheduler (grad all-reduce of
microbatch i overlaps compute of i+1).
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib


def make_train_step(loss_fn: Callable, opt_cfg: opt_lib.AdamWConfig, *,
                    microbatches: int = 1, param_dtype=None,
                    grad_transform: Optional[Callable] = None):
    """loss_fn(params, *batch_leaves) -> scalar.

    ``grad_transform(grads) -> grads`` hooks in gradient compression.
    """

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        else:
            def micro(carry, mb):
                acc, _ = carry
                l, g = jax.value_and_grad(loss_fn)(params, *mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, l), None

            split = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum, loss), _ = jax.lax.scan(micro, (zero, jnp.float32(0)),
                                           split)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_state, stats = opt_lib.apply(
            grads, opt_state, opt_cfg, param_dtype=param_dtype)
        return new_params, new_state, {"loss": loss, **stats}

    return train_step


def fit(train_step, params, opt_state, batches, *, hooks=(),
        checkpoint_fn=None, checkpoint_every: int = 0,
        deadline_per_step: Optional[float] = None):
    """Host driver: iterates batches, runs hooks, optional checkpointing and
    straggler deadline accounting (see train/fault.py)."""
    history = []
    for step, batch in enumerate(batches):
        t0 = time.monotonic()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.monotonic() - t0
        metrics["step_time_s"] = dt
        metrics["straggler"] = bool(deadline_per_step and dt > deadline_per_step)
        history.append(metrics)
        for h in hooks:
            h(step, params, opt_state, metrics)
        if checkpoint_fn and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            checkpoint_fn(step, params, opt_state)
    return params, opt_state, history


__all__ = ["make_train_step", "fit"]
