"""Pallas TPU kernel: batched negacyclic NTT over an RNS prime.

Why a kernel: the RLWE encrypted-distance path (paper Module 2a, TPU-adapted)
is dominated by forward/inverse NTTs over batches of polynomials.  The whole
log2(N)-stage butterfly network runs on a VMEM-resident tile — one HBM read
and one HBM write per polynomial regardless of stage count, with the 10-bit
limb-split Barrett modular multiply (see `crypto/modring.py`) fused into every
butterfly.  All arithmetic is int32; every partial product is < 2^31, so the
kernel targets the TPU's native 32-bit integer lanes (no 64-bit emulation).

Layout: polynomials are (batch, N) int32; the grid tiles the batch dimension.
N is a power of two (256..16384); for N >= 256 rows are a multiple of the
(8, 128) VPU tile after the internal (m, 2, t) reshapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx


def _fwd_kernel(x_ref, psi_ref, o_ref, *, q: int, mu: int, n: int):
    a = x_ref[...]
    psi = psi_ref[...]
    bt = a.shape[0]
    t = n
    m = 1
    while m < n:
        t //= 2
        g = a.reshape(bt, m, 2, t)
        s = jax.lax.dynamic_slice(psi, (m,), (m,)).reshape(1, m, 1)
        u = g[:, :, 0, :]
        v = modring.mod_mul(g[:, :, 1, :], s, q, mu)
        a = jnp.stack(
            [modring.mod_add(u, v, q), modring.mod_sub(u, v, q)], axis=2
        ).reshape(bt, n)
        m *= 2
    o_ref[...] = a


def inv_butterflies(a, ipsi, *, q: int, mu: int, n: int, n_inv: int):
    """Inverse negacyclic butterfly network + final N^{-1} scaling on (bt, n)
    int32 rows.  Shared by the standalone inverse-NTT kernel below and the
    fused re-rank kernel (`kernels/ntt/fused.py`), which absorbs the inverse
    NTT of its accumulators so both run the exact same integer ops —
    bit-identity between the fused and staged pipelines holds by construction.
    """
    bt = a.shape[0]
    t = 1
    m = n
    while m > 1:
        h = m // 2
        g = a.reshape(bt, h, 2, t)
        s = jax.lax.dynamic_slice(ipsi, (h,), (h,)).reshape(1, h, 1)
        u = g[:, :, 0, :]
        v = g[:, :, 1, :]
        a = jnp.stack(
            [
                modring.mod_add(u, v, q),
                modring.mod_mul(modring.mod_sub(u, v, q), s, q, mu),
            ],
            axis=2,
        ).reshape(bt, n)
        t *= 2
        m = h
    return modring.mod_mul(a, jnp.int32(n_inv), q, mu)


def _inv_kernel(x_ref, ipsi_ref, o_ref, *, q: int, mu: int, n: int, n_inv: int):
    o_ref[...] = inv_butterflies(x_ref[...], ipsi_ref[...], q=q, mu=mu, n=n,
                                 n_inv=n_inv)


def _pointwise_kernel(a_ref, b_ref, o_ref, *, q: int, mu: int):
    o_ref[...] = modring.mod_mul(a_ref[...], b_ref[...], q, mu)


def _tile(batch: int, n: int) -> int:
    """Batch tile size so a tile is ~<=1 MiB of VMEM-resident int32."""
    target = max(1, (1 << 20) // (4 * n))
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and batch % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("ctx", "inverse", "interpret"))
def ntt_pallas(x, ctx: PrimeCtx, *, inverse: bool = False, interpret: bool = True):
    """Batched (inverse) negacyclic NTT. x: (batch, N) int32 in [0, q)."""
    batch, n = x.shape
    assert n == ctx.n, (n, ctx.n)
    bt = _tile(batch, n)
    table = jnp.asarray(ctx.ipsi_table if inverse else ctx.psi_table)
    if inverse:
        kern = functools.partial(
            _inv_kernel, q=ctx.q, mu=ctx.mu, n=n, n_inv=ctx.n_inv
        )
    else:
        kern = functools.partial(_fwd_kernel, q=ctx.q, mu=ctx.mu, n=n)
    return pl.pallas_call(
        kern,
        grid=(batch // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.int32),
        interpret=interpret,
    )(x, table)


@functools.partial(jax.jit, static_argnames=("ctx", "interpret"))
def pointwise_mul_pallas(a, b, ctx: PrimeCtx, *, interpret: bool = True):
    """Elementwise modular multiply of NTT-domain polynomials (same shape)."""
    assert a.shape == b.shape
    batch, n = a.shape
    bt = _tile(batch, n)
    return pl.pallas_call(
        functools.partial(_pointwise_kernel, q=ctx.q, mu=ctx.mu),
        grid=(batch // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.int32),
        interpret=interpret,
    )(a, b)


__all__ = ["ntt_pallas", "pointwise_mul_pallas", "inv_butterflies"]
