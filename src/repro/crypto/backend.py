"""The crypto-backend seam: one batched pipeline, interchangeable crypto.

Every stage of the serving stack that used to branch on ``backend ==
"rlwe"`` / ``backend == "paillier"`` now calls through a `CryptoBackend`
instance instead: the staged fault-isolation pipeline in `serve.engine`,
the wire messages and sequential driver in `core.protocol`, and the launch
driver all see the same method surface whichever scheme a tenant group
uses.  Backend choice becomes a pure privacy/latency tradeoff — both
schemes ride the same batching, bisection fault attribution, tracing, and
router scatter-gather.

Method groups:

  user half      `keygen` / `encrypt_query` / `decrypt_reply`
  wire           `request_nbytes` / `reply_nbytes` / `wire_context`
  cloud half     `prepare_cloud` / `score_request` (sequential reference)
  serve batched  `cache_view` / `score_candidates` / `decrypt_scores`

`score_candidates` returns a *score batch* — an object with ``.lanes()``
yielding per-lane ciphertexts for wire replies and bisected fallbacks,
while the engine keeps the whole object alive so `decrypt_scores` can take
a stacked fast path when no lane failed.  RLWE's `ScoreCiphertextBatch`
already has that shape; Paillier gets `PaillierScoreBatch`.

Unknown names raise `UnknownBackend` (a `ValueError`, following the
serve.admission typed-error convention) instead of the old bare assert.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.crypto import paillier as pai
from repro.crypto import paillier_vec as pvec
from repro.crypto import rlwe


class UnknownBackend(ValueError):
    """Raised for a backend name with no registered implementation."""

    def __init__(self, backend: str, known: Sequence[str]):
        self.backend = backend
        self.known = tuple(known)
        super().__init__(
            f"unknown crypto backend {backend!r}; known: {', '.join(known)}")


class CryptoBackend(abc.ABC):
    """Batched crypto operations behind one backend-neutral surface."""

    name: str

    # -- user half ----------------------------------------------------------
    @abc.abstractmethod
    def keygen(self, user) -> object:
        """Key material for a `RemoteRagUser` (reads the user's params/rng)."""

    @abc.abstractmethod
    def encrypt_query(self, user, e: np.ndarray) -> object:
        """Encrypt one embedding under the user's key (module 2a, user half)."""

    @abc.abstractmethod
    def decrypt_reply(self, user, enc_scores) -> np.ndarray:
        """Decrypt one reply's scores (sequential driver tail)."""

    # -- wire accounting ----------------------------------------------------
    @abc.abstractmethod
    def request_nbytes(self, enc_query, *, params, key_bits) -> int:
        """Wire size of an encrypted query."""

    @abc.abstractmethod
    def reply_nbytes(self, enc_scores, *, params, key_bits) -> int:
        """Wire size of a reply's score ciphertexts."""

    @abc.abstractmethod
    def wire_context(self, user) -> tuple:
        """(rlwe params | None, key_bits) for transcript accounting."""

    # -- cloud half ---------------------------------------------------------
    def prepare_cloud(self, cloud, user) -> None:
        """Hand the cloud whatever public material scoring needs."""

    @abc.abstractmethod
    def score_request(self, cloud, req, cand_ids: np.ndarray) -> object:
        """Sequential-path encrypted re-rank for one request."""

    # -- serve layer (batched) ----------------------------------------------
    def cache_view(self, cloud):
        """The candidate cache this backend scores against (None if n/a)."""
        return None

    @abc.abstractmethod
    def score_candidates(self, *, cloud, users, enc, cand_ids, kprime,
                         params, cache, use_pallas) -> object:
        """Batched encrypted re-rank over a lane subset; returns a score
        batch with ``.lanes()``."""

    @abc.abstractmethod
    def decrypt_scores(self, sks, stacked, *, use_pallas) -> List[np.ndarray]:
        """Batched decryption; ``stacked`` is either the score batch from a
        clean full-set `score_candidates` call or a per-lane list after
        bisection."""


class RlweBackend(CryptoBackend):
    """TPU-native batched RLWE (default backend)."""

    name = "rlwe"

    def keygen(self, user):
        return rlwe.keygen(user.rlwe_params, user.rng)

    def encrypt_query(self, user, e):
        return rlwe.encrypt_query(user.sk, e, user.rng)

    def decrypt_reply(self, user, enc_scores):
        return rlwe.decrypt_scores(user.sk, enc_scores)

    def request_nbytes(self, enc_query, *, params, key_bits):
        assert params is not None
        return enc_query.c0.shape[0] * params.ciphertext_bytes()

    def reply_nbytes(self, enc_scores, *, params, key_bits):
        assert params is not None
        return enc_scores.c0.shape[0] * params.ciphertext_bytes()

    def wire_context(self, user):
        return user.rlwe_params, 2048

    def score_request(self, cloud, req, cand_ids):
        cache = cloud.candidate_cache
        if cache is not None:
            return rlwe.encrypted_scores_cached(
                cloud.rlwe_params, req.enc_query, cache, cand_ids,
                use_pallas=cloud.use_pallas)
        cand_rows = np.asarray(cloud.index.rows(cand_ids))
        packed = rlwe.pack_candidates(cloud.rlwe_params, cand_rows)
        return rlwe.encrypted_scores(cloud.rlwe_params, req.enc_query,
                                     packed, use_pallas=cloud.use_pallas)

    def cache_view(self, cloud):
        return cloud.candidate_cache

    def score_candidates(self, *, cloud, users, enc, cand_ids, kprime,
                         params, cache, use_pallas):
        if cache is not None:
            return rlwe.encrypted_scores_cached_batch(
                params, enc, cache, cand_ids, use_pallas=use_pallas)
        rows = np.asarray(cloud.index.rows(cand_ids.reshape(-1)))
        cand_rows = rows.reshape(len(users), kprime, -1)
        packed = rlwe.pack_candidates_batch(params, cand_rows)
        return rlwe.encrypted_scores_batch_stacked(
            params, enc, packed, num_cands=kprime,
            n_dim=cand_rows.shape[-1], use_pallas=use_pallas)

    def decrypt_scores(self, sks, stacked, *, use_pallas):
        return rlwe.decrypt_scores_batch(sks, stacked, use_pallas=use_pallas)


@dataclasses.dataclass
class PaillierScoreBatch:
    """Per-lane Paillier score ciphertexts with the score-batch surface."""

    cts: List[list]

    def lanes(self) -> List[list]:
        return self.cts


class PaillierBackend(CryptoBackend):
    """Paper-faithful Paillier, vectorized over lanes via `paillier_vec`
    (RNS Montgomery kernels) with per-lane object fallback for oversized
    keys.  The sequential `score_request` keeps the object path — it is the
    reference the batched path is differential-tested against."""

    name = "paillier"

    def keygen(self, user):
        return pai.keygen(user.paillier_bits, rng=user._pai_rng)

    def encrypt_query(self, user, e):
        return pvec.encrypt_vector(user.sk.pub, e, user._pai_rng)

    def decrypt_reply(self, user, enc_scores):
        return pai.decrypt_scores(user.sk, enc_scores)

    def request_nbytes(self, enc_query, *, params, key_bits):
        return len(enc_query) * 2 * key_bits // 8

    def reply_nbytes(self, enc_scores, *, params, key_bits):
        return len(enc_scores) * 2 * key_bits // 8

    def wire_context(self, user):
        return None, user.sk.pub.key_bits

    def prepare_cloud(self, cloud, user):
        cloud.register_paillier(user.sk.pub)

    def score_request(self, cloud, req, cand_ids):
        cand_rows = np.asarray(cloud.index.rows(cand_ids))
        return pai.encrypted_scores(cloud._paillier_pub, req.enc_query,
                                    cand_rows)

    def score_candidates(self, *, cloud, users, enc, cand_ids, kprime,
                         params, cache, use_pallas):
        rows = np.asarray(cloud.index.rows(cand_ids.reshape(-1)))
        cand_rows = rows.reshape(len(users), kprime, -1)
        return PaillierScoreBatch(pvec.encrypted_scores_batch(
            [u.sk.pub for u in users], enc, list(cand_rows)))

    def decrypt_scores(self, sks, stacked, *, use_pallas):
        lanes = stacked.lanes() if isinstance(stacked, PaillierScoreBatch) \
            else list(stacked)
        return pvec.decrypt_scores_batch(sks, lanes)


_REGISTRY = {b.name: b for b in (RlweBackend(), PaillierBackend())}


def get_backend(name: str) -> CryptoBackend:
    """Resolve a backend name; raises `UnknownBackend` (ValueError)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownBackend(name, sorted(_REGISTRY)) from None


def available() -> tuple:
    """Registered backend names (launch drivers build --backend from this)."""
    return tuple(sorted(_REGISTRY))


def scores_backend(enc_scores) -> CryptoBackend:
    """Structural dispatch for score ciphertexts whose wire message does
    not carry a backend tag (`protocol.Reply`)."""
    if isinstance(enc_scores, rlwe.ScoreCiphertexts):
        return _REGISTRY["rlwe"]
    return _REGISTRY["paillier"]


__all__ = ["CryptoBackend", "RlweBackend", "PaillierBackend",
           "PaillierScoreBatch", "UnknownBackend", "get_backend",
           "available", "scores_backend"]
