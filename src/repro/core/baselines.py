"""The paper's two baseline services (Section 4.3).

Both are special cases of RemoteRAG:
  * privacy-ignorant  = eps -> inf (no perturbation, plaintext query)
  * privacy-conscious = eps -> 0   (k' = N: PHE over ALL documents + OT)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax.numpy as jnp

from repro.crypto import ot as ot_mod
from repro.crypto import paillier as pai
from repro.crypto import rlwe
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import distributed_topk


@dataclasses.dataclass
class BaselineResult:
    ids: np.ndarray
    docs: Optional[List[bytes]]
    wire_bytes: int


def privacy_ignorant_service(index: FlatIndex, e: np.ndarray, k: int,
                             *, fetch_docs: bool = True) -> BaselineResult:
    """Plaintext query up, top-k docs down. 1 round, n*beta + k*eta."""
    q = jnp.asarray(e, jnp.float32)[None, :]
    res = distributed_topk(index, q, k)
    ids = np.asarray(res.indices)[0]
    docs = index.fetch_documents(ids) if fetch_docs and index.documents else None
    wire = e.size * 4 + (sum(len(d) for d in docs) if docs else 0)
    return BaselineResult(ids=ids, docs=docs, wire_bytes=wire)


def privacy_conscious_service(index: FlatIndex, e: np.ndarray, k: int,
                              *, backend: str = "paillier",
                              paillier_bits: int = 512,
                              rng: Optional[np.random.Generator] = None,
                              run_ot: bool = True) -> BaselineResult:
    """PHE distances over ALL N docs; k-out-of-N OT for retrieval.

    This is the scheme whose cost the paper reports as 2.72 h / 1.43 GB at
    N = 1e6; run it at small N and scale linearly (its cost is exactly linear
    in N by construction — see benchmarks/table4_efficiency.py).
    """
    rng = rng or np.random.default_rng(0)
    rows = np.asarray(index.embeddings)[: index.num_rows]
    wire = 0
    if backend == "paillier":
        sk = pai.keygen(paillier_bits)
        enc_q = pai.encrypt_vector(sk.pub, e)
        wire += len(enc_q) * sk.pub.ciphertext_bytes()
        enc_s = pai.encrypted_scores(sk.pub, enc_q, rows)
        wire += len(enc_s) * sk.pub.ciphertext_bytes()
        scores = pai.decrypt_scores(sk, enc_s)
    else:
        params = rlwe.RlweParams()
        sk = rlwe.keygen(params, rng)
        ct = rlwe.encrypt_query(sk, e, rng)
        wire += ct.c0.shape[0] * params.ciphertext_bytes()
        packed = rlwe.pack_candidates(params, rows)
        enc = rlwe.encrypted_scores(params, ct, packed)
        wire += enc.c0.shape[0] * params.ciphertext_bytes()
        scores = rlwe.decrypt_scores(sk, enc)
    order = np.argsort(-scores[: index.num_rows], kind="stable")[:k]
    docs = None
    if run_ot and index.documents:
        width = max(len(d) for d in index.documents)
        padded = [d.ljust(width, b"\x00") for d in index.documents]
        got, ot_wire = ot_mod.run_ot(padded, [int(i) for i in order])
        docs = [d.rstrip(b"\x00") for d in got]
        wire += ot_wire
    return BaselineResult(ids=np.asarray(order), docs=docs, wire_bytes=wire)


__all__ = ["BaselineResult", "privacy_ignorant_service",
           "privacy_conscious_service"]
